"""Masked class-prototype aggregation as a Pallas kernel.

This is the permutation-invariant SUM at the heart of LITE's unbiasedness
argument (paper Eq. 4): prototypes are class-wise means of support
features, computed here as ``onehot.T @ features`` so that padded /
invalid support slots (all-zero one-hot rows) contribute nothing.

TPU mapping: the kernel is a single-block MXU matmul over a [C_pad, N_pad]
x [N_pad, D_pad] contraction. C (way) is tiny (<=10 padded to 8-multiple),
N <= a few hundred, D = 128 — the whole contraction fits one VMEM tile
(~N_pad * D_pad * 4 bytes ≈ 128 KiB at N=256, D=128), so no grid is needed
and the MXU sees a well-shaped [*,128] operand.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import LANE, SUBLANE, ceil_to, pad_axis


def _sums_kernel(onehot_t_ref, feat_ref, out_ref):
    # out[c, d] = sum_n onehot[n, c] * feat[n, d]  — one MXU matmul.
    out_ref[...] = jnp.dot(
        onehot_t_ref[...], feat_ref[...], preferred_element_type=jnp.float32
    )


@jax.custom_vjp
def proto_sums(features: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Class-wise segment sum. features [N, D], onehot [N, C] -> [C, D]."""
    n, d = features.shape
    _, c = onehot.shape
    n_p = ceil_to(n, SUBLANE)
    d_p = ceil_to(d, LANE)
    c_p = ceil_to(c, SUBLANE)
    feat_p = pad_axis(pad_axis(features, 0, n_p), 1, d_p)
    oh_t_p = pad_axis(pad_axis(onehot.T, 0, c_p), 1, n_p)
    out = pl.pallas_call(
        _sums_kernel,
        out_shape=jax.ShapeDtypeStruct((c_p, d_p), jnp.float32),
        interpret=True,
    )(oh_t_p, feat_p)
    return out[:c, :d]


def _proto_sums_fwd(features, onehot):
    return proto_sums(features, onehot), (features, onehot)


def _proto_sums_bwd(res, g):
    # Pallas interpret kernels don't support reverse-mode AD, so the VJP
    # is spelled out with the tiled Pallas matmul (see dense.py):
    #   d/d feat[n, d] = sum_c onehot[n, c] g[c, d]       = onehot @ g
    #   d/d onehot[n, c] = sum_d feat[n, d] g[c, d]       = feat @ g.T
    features, onehot = res
    from .dense import matmul

    return matmul(onehot, g), matmul(features, g.T)


proto_sums.defvjp(_proto_sums_fwd, _proto_sums_bwd)


def prototypes(features: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Masked class means. [N, D], [N, C] -> [C, D].

    Empty classes (count 0, only possible for padded way slots) get a zero
    prototype rather than NaN.
    """
    sums = proto_sums(features, onehot)
    counts = onehot.sum(axis=0)
    return sums / jnp.maximum(counts, 1.0)[:, None]
