"""FiLM feature modulation as a Pallas kernel.

y = gamma * x + beta, broadcast over the channel (trailing) axis of a
[B, H, W, C] activation map. This is the op the CNAPs hyper-networks
drive; it sits inside every backbone block, so on TPU it must stream
HBM->VMEM efficiently: the kernel flattens the map to [B*H*W, C] rows and
tiles the row axis, with gamma/beta resident across grid steps. Pure VPU
(element-wise) work — no MXU involvement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import LANE, ceil_to, pad_axis, pick_tile

# TPU tile: 64 rows x 128 lanes keeps the block within one VMEM window
# while streaming HBM; interpret mode grows it via pick_tile (see util).
TILE_R = 64
MAX_TILE_R = 1 << 18


def _film_kernel(x_ref, g_ref, b_ref, out_ref):
    out_ref[...] = x_ref[...] * g_ref[...] + b_ref[...]


@jax.custom_vjp
def film(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """x [..., C], gamma/beta [C] -> gamma*x + beta (same shape as x)."""
    orig_shape = x.shape
    ch = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, ch)
    tile_r, r_p = pick_tile(rows, TILE_R, MAX_TILE_R)
    c_p = ceil_to(ch, LANE)
    x_p = pad_axis(pad_axis(x2, 0, r_p), 1, c_p)
    g_p = pad_axis(gamma[None, :], 1, c_p)
    b_p = pad_axis(beta[None, :], 1, c_p)
    out = pl.pallas_call(
        _film_kernel,
        out_shape=jax.ShapeDtypeStruct((r_p, c_p), jnp.float32),
        grid=(r_p // tile_r,),
        in_specs=[
            pl.BlockSpec((tile_r, c_p), lambda i: (i, 0)),
            pl.BlockSpec((1, c_p), lambda i: (0, 0)),
            pl.BlockSpec((1, c_p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_r, c_p), lambda i: (i, 0)),
        interpret=True,
    )(x_p, g_p, b_p)
    return out[:rows, :ch].reshape(orig_shape)


def _film_fwd(x, gamma, beta):
    return film(x, gamma, beta), (x, gamma)


def _film_bwd(res, g):
    # dx = g * gamma (another FiLM application with beta = 0);
    # dgamma / dbeta reduce over all non-channel axes.
    x, gamma = res
    reduce_axes = tuple(range(x.ndim - 1))
    dx = film(g, gamma, jnp.zeros_like(gamma))
    dgamma = jnp.sum(g * x, axis=reduce_axes)
    dbeta = jnp.sum(g, axis=reduce_axes)
    return dx, dgamma, dbeta


film.defvjp(_film_fwd, _film_bwd)
