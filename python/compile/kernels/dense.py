"""Tiled matmul / affine map as Pallas kernels.

y = x @ w (+ b) with the M (row) axis tiled by the grid. Used by the task
encoder MLPs and the FiLM-generator hyper-networks, and as the backward
workhorse for the other kernels' custom VJPs. K and N in this system are
<= a few hundred, so w stays VMEM-resident across grid steps
([K_p, N_p] f32 <= 256x256x4 = 256 KiB) while x streams through in
TILE_M-row blocks — the classic weight-stationary MXU schedule.

Pallas interpret-mode kernels are not reverse-mode differentiable, so the
public entry points carry ``jax.custom_vjp`` definitions whose backward
passes are themselves expressed with the same tiled matmul kernel
(dx = g @ w.T, dw = x.T @ g) — the whole train graph stays on the Pallas
path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import LANE, ceil_to, pad_axis, pick_tile

# TPU tile (see util.pick_tile for the interpret-mode growth rule).
TILE_M = 32
MAX_TILE_M = 4096


def _matmul_kernel(x_ref, w_ref, out_ref):
    out_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Raw tiled matmul. x [M, K], w [K, N] -> [M, N]. Not differentiable —
    used inside forward/backward rules of the differentiable wrappers."""
    m, k = x.shape
    _, n = w.shape
    tile_m, m_p = pick_tile(m, TILE_M, MAX_TILE_M)
    k_p = ceil_to(k, LANE)
    n_p = ceil_to(n, LANE)
    x_p = pad_axis(pad_axis(x, 0, m_p), 1, k_p)
    w_p = pad_axis(pad_axis(w, 0, k_p), 1, n_p)
    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m_p, n_p), jnp.float32),
        grid=(m_p // tile_m,),
        in_specs=[
            pl.BlockSpec((tile_m, k_p), lambda i: (i, 0)),
            pl.BlockSpec((k_p, n_p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, n_p), lambda i: (i, 0)),
        interpret=True,
    )(x_p, w_p)
    return out[:m, :n]


@jax.custom_vjp
def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Affine map. x [M, K], w [K, N], b [N] -> [M, N]."""
    return matmul(x, w) + b


def _dense_fwd(x, w, b):
    return dense(x, w, b), (x, w)


def _dense_bwd(res, g):
    x, w = res
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
