"""Shared helpers for the Pallas kernels.

All kernels in this package run with ``interpret=True`` (the CPU PJRT
plugin cannot execute Mosaic custom-calls; see DESIGN.md §Hardware
adaptation). Tile shapes are nevertheless chosen for the TPU memory
hierarchy: the lane dimension is padded to 128 (VREG lane width) and the
sublane dimension to 8, so the same BlockSpecs would map onto real VMEM
tiles unchanged.
"""

from __future__ import annotations

import jax.numpy as jnp

# TPU vector-register geometry: kernels tile the trailing dim to LANE and
# the second-to-last dim to SUBLANE multiples.
LANE = 128
SUBLANE = 8


def ceil_to(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``n``."""
    return ((n + m - 1) // m) * m


def pad_axis(x: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    """Zero-pad ``x`` along ``axis`` up to length ``target`` (no-op if equal)."""
    cur = x.shape[axis]
    if cur == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(x, widths)


def pad2d(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """Zero-pad the last two axes of ``x`` up to (rows, cols)."""
    x = pad_axis(x, x.ndim - 2, rows)
    return pad_axis(x, x.ndim - 1, cols)


def pick_tile(n: int, preferred: int, max_tile: int) -> tuple:
    """Choose a leading-axis tile for interpret-mode execution.

    On a real TPU the `preferred` tile (sized for VMEM residency) is the
    right block; under interpret=True every grid step lowers to one
    iteration of an XLA while-loop with dynamic slices, so fine grids
    serialize catastrophically on CPU. We therefore grow the tile up to
    `max_tile` so typical shapes need only a handful of grid steps,
    keeping the same BlockSpec structure. Returns (tile, padded_n).
    """
    if n <= max_tile:
        tile = ceil_to(n, preferred)
        return tile, tile
    tile = max_tile
    return tile, ceil_to(n, tile)
