"""Pairwise squared-Euclidean distance as a Pallas kernel (ProtoNets head).

Computes ||x_m - p_c||^2 via the expansion x2 + p2 - 2 x.p so the dominant
cost is a single MXU matmul. The query dimension M is tiled by the grid
(block rows of TILE_M) so arbitrarily large query batches stream through
VMEM; C and D stay resident (C <= ~16 padded, D = 128 -> one lane tile).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import LANE, SUBLANE, ceil_to, pad_axis, pick_tile

# TPU tile: 32x128 f32 = 16 KiB VMEM per block; interpret mode grows it
# (see util.pick_tile).
TILE_M = 32
MAX_TILE_M = 4096


def _sqdist_kernel(x_ref, pt_ref, p2_ref, out_ref):
    x = x_ref[...]  # [TILE_M, D]
    cross = jnp.dot(x, pt_ref[...], preferred_element_type=jnp.float32)  # [TILE_M, C]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # [TILE_M, 1]
    out_ref[...] = x2 + p2_ref[...] - 2.0 * cross


@jax.custom_vjp
def sq_euclidean(x: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """x [M, D], p [C, D] -> [M, C] of squared Euclidean distances."""
    m, d = x.shape
    c, _ = p.shape
    tile_m, m_p = pick_tile(m, TILE_M, MAX_TILE_M)
    d_p = ceil_to(d, LANE)
    c_p = ceil_to(c, SUBLANE)
    x_p = pad_axis(pad_axis(x, 0, m_p), 1, d_p)
    pt = pad_axis(pad_axis(p.T, 0, d_p), 1, c_p)  # [D_p, C_p]
    p2 = pad_axis(jnp.sum(p * p, axis=1)[None, :], 1, c_p)  # [1, C_p]
    grid = (m_p // tile_m,)
    out = pl.pallas_call(
        _sqdist_kernel,
        out_shape=jax.ShapeDtypeStruct((m_p, c_p), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, d_p), lambda i: (i, 0)),
            pl.BlockSpec((d_p, c_p), lambda i: (0, 0)),
            pl.BlockSpec((1, c_p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, c_p), lambda i: (i, 0)),
        interpret=True,
    )(x_p, pt, p2)
    return out[:m, :c]


def _sq_euclidean_fwd(x, p):
    return sq_euclidean(x, p), (x, p)


def _sq_euclidean_bwd(res, g):
    # d out[m,c] / d x[m,d] = 2 (x[m,d] - p[c,d])
    #   => dx = 2 (x * rowsum(g) - g @ p),  dp = 2 (p * colsum(g) - g.T @ x)
    # The cross terms are MXU matmuls — routed through the Pallas matmul.
    x, p = res
    from .dense import matmul

    dx = 2.0 * (x * jnp.sum(g, axis=1, keepdims=True) - matmul(g, p))
    dp = 2.0 * (p * jnp.sum(g, axis=0)[:, None] - matmul(g.T, x))
    return dx, dp


sq_euclidean.defvjp(_sq_euclidean_fwd, _sq_euclidean_bwd)
