"""Artifact configuration registry.

Every HLO artifact the rust coordinator can load is described here by an
``ArtifactSpec``; ``registry()`` enumerates the full set that
``aot.py`` lowers. Names are stable identifiers — the rust side addresses
artifacts exclusively through ``artifacts/manifest.json`` entries keyed by
these names.

Scale notes (DESIGN.md §3): image sizes 32/64/96 stand in for the paper's
84/224/320; support sizes are scaled 1000 -> <=200.
"""

from __future__ import annotations

from dataclasses import dataclass, field

SMALL, LARGE, XLARGE = 32, 64, 96
FEATURE_DIM = 128
PRETRAIN_CLASSES = 20
PRETRAIN_BATCH = 32


@dataclass(frozen=True)
class Geometry:
    """Static task geometry baked into a train artifact.

    way: padded class count C.
    n_support: padded total support size N.
    h: LITE back-prop subset size; h == 0 means NO support gradients
       (ProtoNets' |H|=0 column in Table 2); h == n_support means exact
       full-support back-prop (no nbp split).
    mb: query batch size per train step (Algorithm 1's M_b).
    """

    way: int
    n_support: int
    h: int
    mb: int

    @property
    def n_nbp(self) -> int:
        return self.n_support - self.h

    def tag(self) -> str:
        return f"w{self.way}n{self.n_support}h{self.h}m{self.mb}"


@dataclass(frozen=True)
class TestGeometry:
    """Static geometry for adapt/classify artifacts."""

    way: int
    n_support: int
    mq: int  # query batch size per classify call

    def tag(self) -> str:
        return f"w{self.way}n{self.n_support}q{self.mq}"


@dataclass(frozen=True)
class ArtifactSpec:
    name: str
    model: str  # protonet | cnaps | simple_cnaps | maml | finetuner | pretrain
    kind: str  # train | adapt | classify | features | head_step | head_predict | pretrain_step
    image_size: int = 0
    geom: Geometry | None = None
    test_geom: TestGeometry | None = None
    extra: dict = field(default_factory=dict)


# Default geometries (overridable by editing this registry). WAY is the
# global padded class count: every artifact uses the same width so trained
# tensors (e.g. MAML's head) are shape-stable across train/test.
WAY = 10
TRAIN_GEOM = Geometry(way=WAY, n_support=40, h=8, mb=10)
SWEEP_N = 80
TEST_GEOM = TestGeometry(way=WAY, n_support=200, mq=20)
ORBIT_TEST_GEOM = TestGeometry(way=WAY, n_support=64, mq=16)

META_MODELS = ("protonet", "cnaps", "simple_cnaps")
GRADCHECK_GEOM = dict(way=10, n_support=100, mb=10)
GRADCHECK_HS = (10, 20, 30, 40, 50, 60, 70, 80, 90)
# Fusion widths for cross-episode megabatching: each megatrain artifact
# packs W structurally-identical copies of the model's train step into
# one device dispatch (slot-major s{k}.* inputs/outputs). The rust
# coordinator resolves `--megabatch N` against these widths.
MEGA_WIDTHS = (2, 4)


def _train(model: str, size: int, geom: Geometry) -> ArtifactSpec:
    return ArtifactSpec(
        name=f"{model}_{size}_{geom.tag()}_train",
        model=model,
        kind="train",
        image_size=size,
        geom=geom,
    )


def _megatrain(model: str, size: int, geom: Geometry, width: int, extra: dict | None = None) -> ArtifactSpec:
    e = dict(extra or {})
    e["fuse"] = width
    return ArtifactSpec(
        name=f"{model}_{size}_{geom.tag()}_mega{width}_train",
        model=model,
        kind="megatrain",
        image_size=size,
        geom=geom,
        extra=e,
    )


def _megaclassify(model: str, size: int, tg: TestGeometry, width: int) -> ArtifactSpec:
    return ArtifactSpec(
        name=f"{model}_{size}_{tg.tag()}_mega{width}_classify",
        model=model,
        kind="megaclassify",
        image_size=size,
        test_geom=tg,
        extra=dict(fuse=width),
    )


def _adapt_classify(model: str, size: int, tg: TestGeometry) -> list:
    return [
        ArtifactSpec(
            name=f"{model}_{size}_{tg.tag()}_adapt",
            model=model,
            kind="adapt",
            image_size=size,
            test_geom=tg,
        ),
        ArtifactSpec(
            name=f"{model}_{size}_{tg.tag()}_classify",
            model=model,
            kind="classify",
            image_size=size,
            test_geom=tg,
        ),
    ]


def registry() -> list:
    specs: list[ArtifactSpec] = []
    for size in (SMALL, LARGE):
        # Supervised pretraining of the shared backbone (frozen afterwards
        # for CNAPs variants + FineTuner; DESIGN.md substitution table).
        specs.append(
            ArtifactSpec(
                name=f"pretrain_{size}_step",
                model="pretrain",
                kind="pretrain_step",
                image_size=size,
                extra=dict(classes=PRETRAIN_CLASSES, batch=PRETRAIN_BATCH),
            )
        )
        # Meta-learners: LITE train step + adapt/classify pair.
        for model in META_MODELS:
            specs.append(_train(model, size, TRAIN_GEOM))
            for w in MEGA_WIDTHS:
                specs.append(_megatrain(model, size, TRAIN_GEOM, w))
            specs += _adapt_classify(model, size, TEST_GEOM)
            specs += _adapt_classify(model, size, ORBIT_TEST_GEOM)
            # Serving-side cross-user fusion: W classify calls, each
            # against its own slot's adapted state, in one dispatch
            # (`lite serve`'s micro-batcher; MAML adapts per-user
            # parameter trees too large to pin at scale, so only the
            # amortized-adaptation meta-learners get fused classify).
            for tg in (TEST_GEOM, ORBIT_TEST_GEOM):
                for w in MEGA_WIDTHS:
                    specs.append(_megaclassify(model, size, tg, w))
        # First-order MAML baseline (no LITE; inner loop in-graph). h=0
        # geometry => a single full support buffer, no LITE split.
        maml_geom = Geometry(way=WAY, n_support=TRAIN_GEOM.n_support, h=0, mb=TRAIN_GEOM.mb)
        specs.append(
            ArtifactSpec(
                name=f"maml_{size}_{maml_geom.tag()}_train",
                model="maml",
                kind="train",
                image_size=size,
                geom=maml_geom,
                extra=dict(inner_steps=3, inner_lr=0.05),
            )
        )
        for w in MEGA_WIDTHS:
            specs.append(
                _megatrain("maml", size, maml_geom, w, dict(inner_steps=3, inner_lr=0.05))
            )
        for tg in (TEST_GEOM, ORBIT_TEST_GEOM):
            specs += [
                ArtifactSpec(
                    name=f"maml_{size}_{tg.tag()}_adapt",
                    model="maml",
                    kind="adapt",
                    image_size=size,
                    test_geom=tg,
                    extra=dict(inner_steps=5, inner_lr=0.05),
                ),
                ArtifactSpec(
                    name=f"maml_{size}_{tg.tag()}_classify",
                    model="maml",
                    kind="classify",
                    image_size=size,
                    test_geom=tg,
                ),
            ]
        # FineTuner: frozen features + SGD'd linear head (steps run by L3).
        specs.append(
            ArtifactSpec(
                name=f"finetuner_{size}_features",
                model="finetuner",
                kind="features",
                image_size=size,
                extra=dict(batch=16),
            )
        )
    # Head artifacts are image-size independent (operate on [B, D] feats).
    specs.append(
        ArtifactSpec(
            name="finetuner_head_step",
            model="finetuner",
            kind="head_step",
            extra=dict(way=10, batch=64, lr=0.1),
        )
    )
    specs.append(
        ArtifactSpec(
            name="finetuner_head_predict",
            model="finetuner",
            kind="head_predict",
            extra=dict(way=10, batch=64),
        )
    )

    # |H| sweep artifacts (Table 2 / D.4–D.6): larger support pool.
    for h in (1, 10, 40, SWEEP_N):
        specs.append(
            _train("simple_cnaps", LARGE, Geometry(way=WAY, n_support=SWEEP_N, h=h, mb=10))
        )
    for h in (0, 10, 40, SWEEP_N):
        specs.append(
            _train("protonet", LARGE, Geometry(way=WAY, n_support=SWEEP_N, h=h, mb=10))
        )
    for h in (40, SWEEP_N):  # 32px right-hand columns of Table 2
        specs.append(
            _train("simple_cnaps", SMALL, Geometry(way=WAY, n_support=SWEEP_N, h=h, mb=10))
        )

    # "Even larger images" run (Table D.9): 96px Simple CNAPs.
    specs.append(
        _train("simple_cnaps", XLARGE, Geometry(way=WAY, n_support=40, h=8, mb=10))
    )
    specs += _adapt_classify("simple_cnaps", XLARGE, TEST_GEOM)
    specs.append(
        ArtifactSpec(
            name=f"pretrain_{XLARGE}_step",
            model="pretrain",
            kind="pretrain_step",
            image_size=XLARGE,
            extra=dict(classes=PRETRAIN_CLASSES, batch=PRETRAIN_BATCH),
        )
    )

    # Gradient-estimator lab (Fig 4 / D.7–D.8): Simple CNAPs at 32px,
    # 10-way 10-shot N=100. "lite_h" back-props h of 100; "sub_n" is the
    # subsampled-small-task baseline (a full-gradient step on n examples).
    g = GRADCHECK_GEOM
    specs.append(
        _train("simple_cnaps", SMALL, Geometry(g["way"], g["n_support"], g["n_support"], g["mb"]))
    )  # exact full gradient
    for h in GRADCHECK_HS:
        specs.append(
            _train("simple_cnaps", SMALL, Geometry(g["way"], g["n_support"], h, g["mb"]))
        )
        specs.append(
            _train("simple_cnaps", SMALL, Geometry(g["way"], h, h, g["mb"]))
        )  # subsampled small task: N = h, exact
    # Dedup (some geometries coincide).
    seen, out = set(), []
    for s in specs:
        if s.name not in seen:
            seen.add(s.name)
            out.append(s)
    return out


def spec_by_name(name: str) -> ArtifactSpec:
    for s in registry():
        if s.name == name:
            return s
    raise KeyError(name)
