//! ORBIT-style personalization: meta-train Simple CNAPs + LITE on
//! simulated users, then personalize to an unseen user's objects from
//! just their clean videos and evaluate on clean AND clutter query
//! videos — the paper's teachable-object-recognizer scenario.
//!
//! Run with: `cargo run --release --example orbit_personalization`

use anyhow::Result;
use lite::coordinator::{meta_train_with, pretrained_backbone, MetaLearner, TrainConfig};
use lite::data::orbit::{OrbitSim, VideoMode};
use lite::data::{EpisodeConfig, Rng};
use lite::eval::score_episode;
use lite::runtime::Engine;
use lite::util::timed;

fn main() -> Result<()> {
    let engine = Engine::load(Engine::default_dir())?;
    let size = 32;

    // Meta-train on 6 simulated "train users" (disjoint from test).
    let mut learner = MetaLearner::new(&engine, "simple_cnaps", size, None, Some(40), 64)?;
    let bb = pretrained_backbone(&engine, size, 150, 0)?;
    learner.install_backbone(&bb);
    let cfg = TrainConfig {
        episodes: std::env::var("ORBIT_EPISODES").ok().and_then(|v| v.parse().ok()).unwrap_or(120),
        accum_period: 4,
        lr: 1e-3,
        seed: 0,
        log_every: 25,
        episode_cfg: EpisodeConfig::train_default(),
        ..Default::default()
    };
    let train_sim = OrbitSim::new(0x0B17, 6);
    meta_train_with(&engine, &mut learner, &cfg, move |rng| {
        let user = rng.below(train_sim.users.len());
        train_sim.user_episode(user, VideoMode::Clean, rng, size, 4, 1, 2)
    })?;

    // Personalize to unseen test users.
    let test_sim = OrbitSim::new(0x7E57, 3);
    println!("\npersonalization on unseen users (support: clean videos only):");
    println!("{:<6} {:>8} {:>12} {:>12} {:>12} {:>10}", "user", "objects", "clean-frame", "clut-frame", "clut-video", "s/task");
    for user in 0..test_sim.users.len() {
        let mut rng = Rng::new(user as u64 + 9);
        let clean_ep = test_sim.user_episode(user, VideoMode::Clean, &mut rng, size, 6, 2, 4);
        let clut_ep = test_sim.user_episode(user, VideoMode::Clutter, &mut rng, size, 6, 2, 4);
        let (clean_preds, dt) = timed(|| learner.predict_episode(&engine, &clean_ep));
        let clean = score_episode(&clean_ep, &clean_preds?);
        let clut = score_episode(&clut_ep, &learner.predict_episode(&engine, &clut_ep)?);
        println!(
            "{:<6} {:>8} {:>12.3} {:>12.3} {:>12.3} {:>10.2}",
            user,
            test_sim.users[user].objects.len(),
            clean.frame_acc,
            clut.frame_acc,
            clut.video_acc,
            dt
        );
    }
    println!("\n(clutter < clean is expected — the paper's Table 1 gap.)");
    Ok(())
}
