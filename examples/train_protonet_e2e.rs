//! END-TO-END driver (DESIGN.md deliverable): exercises the full stack
//! on a real small workload —
//!   1. supervised-pretrain the shared backbone on the synthetic corpus,
//!   2. meta-train ProtoNets with LITE for a few hundred episodes on the
//!      synthetic MD suite, logging the loss curve,
//!   3. meta-test on held-out episodes of every dataset and report the
//!      before/after accuracy.
//!
//! The run recorded in EXPERIMENTS.md §E2E used:
//!   cargo run --release --example train_protonet_e2e

use anyhow::Result;
use lite::coordinator::{meta_train, pretrained_backbone, MetaLearner, TrainConfig};
use lite::data::{md_suite, EpisodeConfig};
use lite::eval::{eval_dataset, Predictor};
use lite::runtime::Engine;

fn main() -> Result<()> {
    let episodes: usize = std::env::var("E2E_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let engine = Engine::load(Engine::default_dir())?;
    let size = 32;

    // --- 1. backbone pretraining (ImageNet stand-in) ------------------
    let bb = pretrained_backbone(&engine, size, 150, 0)?;

    // --- 2. meta-train ProtoNets + LITE -------------------------------
    let mut learner = MetaLearner::new(&engine, "protonet", size, None, Some(40), 200)?;
    learner.install_backbone(&bb);

    // Before-training accuracy snapshot.
    let suite = md_suite();
    let test_cfg = EpisodeConfig::test_large(200);
    let before = mean_acc(&engine, &learner, &suite, &test_cfg, size)?;

    let cfg = TrainConfig {
        episodes,
        accum_period: 8,
        lr: 1e-3,
        seed: 0,
        log_every: 25,
        episode_cfg: EpisodeConfig::train_default(),
        ..Default::default()
    };
    let logs = meta_train(&engine, &mut learner, &suite, &cfg)?;

    // Loss curve (bucketed means so the trend is obvious in a terminal).
    println!("\nloss curve (25-episode buckets):");
    for chunk in logs.chunks(25) {
        let m: f64 = chunk.iter().map(|l| l.loss as f64).sum::<f64>() / chunk.len() as f64;
        let bar = "#".repeat((m * 20.0).min(60.0) as usize);
        println!("  ep {:>4}..{:>4}  loss {m:.4}  {bar}", chunk[0].step, chunk.last().unwrap().step);
    }

    // --- 3. meta-test -------------------------------------------------
    let after = mean_acc(&engine, &learner, &suite, &test_cfg, size)?;
    println!("\nper-dataset accuracy (200-image support tasks):");
    println!("{:<20} {:>8} {:>8}", "dataset", "before", "after");
    for (name, b, a) in before.1.iter().zip(&after.1).map(|((n, b), (_, a))| (n, b, a)) {
        println!("{name:<20} {b:>8.3} {a:>8.3}");
    }
    println!("{:<20} {:>8.3} {:>8.3}", "MEAN", before.0, after.0);

    let ckpt = Engine::default_dir().join("protonet_32_e2e.ckpt");
    learner.params.save(&ckpt)?;
    println!("\ncheckpoint: {}", ckpt.display());
    Ok(())
}

#[allow(clippy::type_complexity)]
fn mean_acc(
    engine: &Engine,
    learner: &MetaLearner,
    suite: &[lite::data::Dataset],
    cfg: &EpisodeConfig,
    size: usize,
) -> Result<(f64, Vec<(String, f64)>)> {
    let mut rows = Vec::new();
    for ds in suite {
        let s = eval_dataset(engine, &Predictor::Meta(learner), ds, cfg, size, 3, 123)?;
        rows.push((ds.name().to_string(), s.frame_acc.0));
    }
    let mean = rows.iter().map(|(_, a)| *a).sum::<f64>() / rows.len() as f64;
    Ok((mean, rows))
}
