//! Quickstart: load the AOT artifacts, adapt a ProtoNet to one few-shot
//! task with a single forward pass, and classify its queries.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first).

use anyhow::Result;
use lite::coordinator::MetaLearner;
use lite::data::{md_suite, sample_episode, EpisodeConfig, Rng};
use lite::eval::score_episode;
use lite::runtime::Engine;

fn main() -> Result<()> {
    // 1. Runtime: PJRT CPU client + the artifact manifest.
    let engine = Engine::load(Engine::default_dir())?;

    // 2. A meta-learner wired from the manifest (32px ProtoNet, with the
    //    large-support test geometry).
    let learner = MetaLearner::new(&engine, "protonet", 32, None, Some(40), 200)?;
    println!(
        "model: {} | {} params ({} learnable)",
        learner.model,
        learner.params.n_params(),
        learner.params.n_learnable()
    );

    // 3. A few-shot episode from the synthetic birds-like dataset.
    let suite = md_suite();
    let birds = suite.iter().find(|d| d.name() == "birds-like").unwrap();
    let mut rng = Rng::new(42);
    let cfg = EpisodeConfig::test_large(200);
    let episode = sample_episode(birds, &cfg, &mut rng, 32);
    println!(
        "episode: {}-way, {} support, {} query images",
        episode.way,
        episode.n_support(),
        episode.query.len()
    );

    // 4. Adapt (ONE forward pass of the support set — the meta-learner
    //    advantage the paper quantifies in Table 1) and classify.
    let preds = learner.predict_episode(&engine, &episode)?;
    let m = score_episode(&episode, &preds);
    println!("accuracy (untrained init): {:.3}", m.frame_acc);
    println!("\nNext: `lite train --model protonet` to meta-train, then re-run.");
    Ok(())
}
