//! The LITE memory/accuracy trade-off (paper §5.3): sweep |H| on the
//! Simple CNAPs sweep artifacts and print, for each setting, the
//! analytic peak training memory next to a short-run accuracy probe —
//! the dial the paper exposes between GPU memory and gradient quality.
//!
//! Run with: `cargo run --release --example h_sweep`

use anyhow::Result;
use lite::coordinator::{meta_train, pretrained_backbone, MetaLearner, TrainConfig};
use lite::data::{md_suite, EpisodeConfig};
use lite::eval::{eval_dataset, Predictor};
use lite::memory::{mib, peak_bytes, Mode};
use lite::runtime::Engine;

fn main() -> Result<()> {
    let engine = Engine::load(Engine::default_dir())?;
    let size = 32;
    let n = 80;
    let episodes: usize = std::env::var("SWEEP_EPISODES").ok().and_then(|v| v.parse().ok()).unwrap_or(60);

    println!("LITE |H| sweep — Simple CNAPs, {size}px, support pool N={n}");
    println!("{:>5} {:>14} {:>12}", "|H|", "peak mem (MiB)", "probe acc");
    for h in [40usize, 80] {
        let mut learner = MetaLearner::new(&engine, "simple_cnaps", size, Some(h), Some(n), 200)?;
        let bb = pretrained_backbone(&engine, size, 150, 0)?;
        learner.install_backbone(&bb);
        let cfg = TrainConfig {
            episodes,
            accum_period: 4,
            lr: 1e-3,
            seed: 0,
            log_every: 0,
            episode_cfg: EpisodeConfig { way_max: 10, shot_min: 2, shot_max: 12, n_support_max: n, query_per_class: 1 },
            ..Default::default()
        };
        meta_train(&engine, &mut learner, &md_suite(), &cfg)?;
        let mut accs = Vec::new();
        for ds in md_suite() {
            let s = eval_dataset(&engine, &Predictor::Meta(&learner), &ds, &EpisodeConfig::test_large(200), size, 2, 5)?;
            accs.push(s.frame_acc.0);
        }
        let mem = if h >= n {
            peak_bytes(Mode::Full, size, n, 10)
        } else {
            peak_bytes(Mode::Lite { h, chunk: 8 }, size, n, 10)
        };
        println!("{:>5} {:>14.1} {:>12.3}", h, mib(mem), lite::util::mean(&accs));
    }
    println!("\n(64px rows of Table 2 regenerate via `lite bench-hsweep`.)");
    Ok(())
}
